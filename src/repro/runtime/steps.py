"""Distributed train / prefill / decode steps (shard_map over the mesh).

This is where the paper's two schemes become end-to-end training modes:

* ``mode="hier"``  — parameters + optimizer state live ONCE per pod, sharded
  over the ``data`` axis (the MPI-3 shared window); layer weights are
  all-gathered intra-pod at use (children load from the node buffer); the
  gradient bridge is: AD-transposed intra-pod reduce-scatter, then ONE
  cross-pod psum per shard (the multi-leader bridge exchange).
* ``mode="naive"`` — pure-MPI analogue: every chip a full private replica,
  one flat (pod, data) psum per gradient.

TP ("model" axis) sharding is identical in both — the paper keeps
computational parallelism unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.substrate.compat import shard_map

from repro.comm import Communicator
from repro.core.topology import MeshTopology
from repro.models.meta import PMeta
from repro.models.parallel import ParallelCtx
from repro.models.transformer import Model, build
from repro.optim.adamw import adamw_init, adamw_update
from repro.configs.base import ModelConfig


def make_ctx(topo: MeshTopology, mode: str,
             compute_dtype=jnp.bfloat16, opts=()) -> ParallelCtx:
    has_pod = "pod" in topo.axis_sizes
    return ParallelCtx(
        tp_axis="model",
        fsdp_axes=("data",) if mode == "hier" else (),
        dp_axes=(("pod", "data") if has_pod else ("data",)),
        pod_axis="pod" if has_pod else None,
        tp=topo.size("model"),
        mode=mode,
        compute_dtype=compute_dtype,
        opts=frozenset(opts))


def build_model(cfg: ModelConfig, topo: MeshTopology, mode: str,
                compute_dtype=jnp.bfloat16, opts=()) -> Model:
    ctx = make_ctx(topo, mode, compute_dtype, opts)
    return build(cfg, ctx, data=topo.size("data"))


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, topo: MeshTopology) -> dict:
    dp = ("pod", "data") if "pod" in topo.axis_sizes else ("data",)
    dp = tuple(a for a in dp if a in topo.axis_sizes)
    if cfg.frontend == "encodec":
        return {"frames": P(dp), "labels": P(dp)}
    out = {"tokens": P(dp)}
    if cfg.frontend == "vit":
        out["patches"] = P(dp)
    return out


def grad_reduce_axes(meta: PMeta, ctx: ParallelCtx) -> tuple[str, ...]:
    """Axes a gradient leaf still needs to be summed over.

    Thin wrapper over ``ParallelCtx.grad_reduce_axes`` — the logic moved
    there so ``reduce_grads`` and the step-graph optimizer share one source
    of truth; this spelling stays for existing callers.
    """
    return ctx.grad_reduce_axes(meta)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainStepBundle:
    fn: Any                 # jittable (state, batch) -> (state, metrics)
    state_specs: Any
    batch_spec: Any
    model: Model

    def init_state(self, seed: int = 0):
        params = self.model.init_params(seed)
        m, v = adamw_init(params)
        return {"params": params, "m": m, "v": v,
                "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, topo: MeshTopology, mesh, *,
                    mode: str = "hier", lr: float = 3e-4,
                    weight_decay: float = 0.1, clip: float = 1.0,
                    unroll: int = 1, compress=None, opts=(),
                    compute_dtype=jnp.bfloat16) -> TrainStepBundle:
    model = build_model(cfg, topo, mode, compute_dtype, opts)
    # the int8_bridge opt is now a precision constraint, not a function:
    # auto-resolution picks the quantized wire scheme from the registry
    grad_precision = "lossy" if (compress is None
                                 and "int8_bridge" in opts) else "exact"
    ctx = model.ctx
    defs = model.defs
    pspecs = model.param_specs()
    bspec = batch_specs(cfg, topo)
    state_specs = {"params": pspecs, "m": pspecs, "v": pspecs, "step": P()}
    meta_leaves = jax.tree.leaves(defs,
                                  is_leaf=lambda x: isinstance(x, PMeta))
    # world communicator over the whole mesh: metric reductions cross both
    # tiers; the grad-norm reduction is node-local (pods hold identical
    # grads after the bridge), i.e. the split_type(SHARED) communicator.
    world = Communicator.from_topology(topo)
    node = world.split_type_shared()

    from repro.models.transformer import _loss  # local-body entry

    def body(state, batch):
        params = state["params"]

        def lf(p):
            loss, cnt = _loss(cfg, ctx, defs, p, batch, unroll=unroll)
            return loss, cnt

        (loss_sum, cnt), grads = jax.value_and_grad(lf, has_aux=True)(params)
        # scheme="auto": the tuning table picks the reduction schedule per
        # topology/size; the replicated constraint (not a scheme name)
        # keeps the result a plain per-rank scalar, never a window.
        # The gradient bridge (the paper's scheme vs the flat pure-MPI
        # reduce) goes through ctx.reduce_grads; with the stepgraph opt the
        # whole schedule is recorded first, then bucketed/reordered and run
        # as one optimized schedule — outputs bit-identical either way.
        if ctx.stepgraph:
            rec = world.record()
            rl = rec.allreduce(loss_sum, axes=world.axes, scheme="auto",
                               result="replicated", bucketable=False,
                               key="loss")
            rc = rec.allreduce(cnt, axes=world.axes, scheme="auto",
                               result="replicated", bucketable=False,
                               key="cnt")
            grads = ctx.reduce_grads(grads, meta_leaves, compress=compress,
                                     recorder=rec,
                                     precision=grad_precision)
            res = rec.run()
            loss_g, cnt_g = res[rl], res[rc]
            grads = res.resolve(grads)
        else:
            loss_g = world.allreduce(loss_sum, result="replicated")
            cnt_g = world.allreduce(cnt, result="replicated")
            grads = ctx.reduce_grads(grads, meta_leaves, compress=compress,
                                     precision=grad_precision)
        grads = jax.tree.map(lambda g: g / cnt_g, grads)

        # global grad norm: each leaf is tiled over the axes it is sharded on
        # and replicated over the rest of the node tier — weight the square
        # by 1/replication so the reduction counts every element exactly
        # once.  Node-local: grads are pod-identical after the bridge.
        gsq = jnp.float32(0.0)
        for g, meta in zip(jax.tree.leaves(grads), meta_leaves):
            repl = 1.0
            if meta.tp_dim is None and "model" in topo.axis_sizes:
                repl *= topo.size("model")
            data_sharded = (ctx.mode == "hier" and meta.fsdp_dim is not None)
            if not data_sharded and "data" in topo.axis_sizes:
                repl *= topo.size("data")
            gsq += jnp.sum(jnp.square(g.astype(jnp.float32))) / repl
        gsq = node.allreduce(gsq, result="replicated")
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        new_params, new_m, new_v = adamw_update(
            params, grads, state["m"], state["v"], state["step"] + 1,
            lr=lr, weight_decay=weight_decay)
        new_state = {"params": new_params, "m": new_m, "v": new_v,
                     "step": state["step"] + 1}
        metrics = {"loss": loss_g / cnt_g, "gnorm": gnorm, "tokens": cnt_g}
        return new_state, metrics

    smapped = shard_map(
        body, mesh=mesh, in_specs=(state_specs, bspec),
        out_specs=(state_specs, {"loss": P(), "gnorm": P(), "tokens": P()}),
        check_vma=False)
    return TrainStepBundle(fn=smapped, state_specs=state_specs,
                           batch_spec=bspec, model=model)


# ---------------------------------------------------------------------------
# End-to-end step-time bench body (the repro.bench "step_time" family)
# ---------------------------------------------------------------------------

def cluster_ctx(vc, *, mode: str = "hier", compute_dtype=jnp.float32,
                opts=()) -> ParallelCtx:
    """A ``ParallelCtx`` over a bench ``VirtualCluster``'s OWN axis names.

    ``make_ctx`` hardcodes the production ``("pod", "data", "model")`` mesh;
    the bench topology matrix names its axes per cluster.  Mapping: the slow
    tier is the bridge, the fast tier is where parameters are stored — and
    when the fast tier is factored over several axes (the ``(dp, tp)``
    tuple mesh) the LAST fast axis plays tensor-parallel, mirroring the
    production layout.
    """
    if len(vc.slow_names) > 1:
        raise ValueError("cluster_ctx supports at most one slow (bridge) "
                         f"axis, got {vc.slow_names}")
    pod = vc.slow_names[0] if vc.slow_names else None
    fast = vc.fast_names
    tp_axis = fast[-1] if len(fast) > 1 else None
    store = fast[:-1] if len(fast) > 1 else fast
    store_size = 1
    for name, size in zip(vc.axis_names, vc.axis_shapes):
        if name in store:
            store_size *= size
    if store_size == 1:
        # a size-1 store shards nothing, so there is no window gather to
        # issue early: the prefetch schedule degrades to the eager path
        # (same program) instead of paying the handle plumbing for no-ops
        opts = tuple(o for o in opts if not str(o).startswith("prefetch"))
    return ParallelCtx(
        tp_axis=tp_axis,
        fsdp_axes=store if mode == "hier" else (),
        dp_axes=((pod,) + store) if pod else store,
        pod_axis=pod,
        tp=vc.fast_shape[-1] if tp_axis else 1,
        mode=mode, compute_dtype=compute_dtype, opts=frozenset(opts))


def make_cluster_train_step(cfg: ModelConfig, vc, *, mode: str = "hier",
                            lr: float = 3e-4, weight_decay: float = 0.1,
                            clip: float = 1.0, unroll: int = 1,
                            global_batch: int = 8, opts=(),
                            compute_dtype=jnp.float32) -> TrainStepBundle:
    """``make_train_step`` over a ``VirtualCluster``'s OWN mesh and axis
    names — the elastic runtime's step builder.

    After a pod loss the runtime calls this again with the SURVIVING
    cluster: ``cluster_ctx`` re-maps the tiers, the world communicator is
    rebuilt via ``Communicator.from_cluster`` (the blessed constructor —
    static pods/chips counts feed the tuning-table signature), and
    ``scheme="auto"`` re-resolves against the new signature at trace time.
    When ``global_batch`` does not divide the surviving data-parallel rank
    count (e.g. 8 ranks -> 7 after a node loss), the batch is REPLICATED
    instead of sharded — every rank computes the full batch and the
    ``cnt`` normalization absorbs the overcount, so the update math is
    unchanged and no topology is unreachable after a shrink.
    """
    if cfg.frontend not in (None, "", "tokens"):
        raise ValueError(f"cluster train step only drives the token "
                         f"frontend, not {cfg.frontend!r}")
    ctx = cluster_ctx(vc, mode=mode, compute_dtype=compute_dtype, opts=opts)
    sizes = dict(zip(vc.axis_names, vc.axis_shapes))
    data = 1
    for a in (ctx.fsdp_axes or tuple(a for a in ctx.dp_axes
                                     if a != ctx.pod_axis)):
        data *= sizes[a]
    model = build(cfg, ctx, data=data)
    defs = model.defs
    pspecs = model.param_specs(tp_axis=ctx.tp_axis,
                               fsdp_axis=ctx.fsdp_axes[0]
                               if ctx.fsdp_axes else None)
    state_specs = {"params": pspecs, "m": pspecs, "v": pspecs, "step": P()}
    n_dp = 1
    for a in ctx.dp_axes:
        n_dp *= sizes[a]
    shard_batch = global_batch % n_dp == 0
    bspec = {"tokens": P(ctx.dp_axes) if shard_batch else P()}
    meta_leaves = jax.tree.leaves(defs,
                                  is_leaf=lambda x: isinstance(x, PMeta))
    world = Communicator.from_cluster(vc)
    node = world.split_type_shared()

    from repro.models.transformer import _loss  # local-body entry

    def body(state, batch):
        params = state["params"]

        def lf(p):
            return _loss(cfg, ctx, defs, p, batch, unroll=unroll)

        (loss_sum, cnt), grads = jax.value_and_grad(lf, has_aux=True)(params)
        # scheme="auto" + replicated constraint, exactly as the production
        # train step: post-shrink this re-resolves against the NEW topology
        # signature (measured entries where the bench swept it, modeled
        # closed forms where it did not).
        if ctx.stepgraph:
            rec = world.record()
            rl = rec.allreduce(loss_sum, axes=world.axes, scheme="auto",
                               result="replicated", bucketable=False,
                               key="loss")
            rc = rec.allreduce(cnt, axes=world.axes, scheme="auto",
                               result="replicated", bucketable=False,
                               key="cnt")
            grads = ctx.reduce_grads(grads, meta_leaves, recorder=rec)
            res = rec.run()
            loss_g, cnt_g = res[rl], res[rc]
            grads = res.resolve(grads)
        else:
            loss_g = world.allreduce(loss_sum, result="replicated")
            cnt_g = world.allreduce(cnt, result="replicated")
            grads = ctx.reduce_grads(grads, meta_leaves)
        grads = jax.tree.map(lambda g: g / cnt_g, grads)
        gsq = jnp.float32(0.0)
        for g, meta in zip(jax.tree.leaves(grads), meta_leaves):
            repl = 1.0
            if meta.tp_dim is None and ctx.tp_axis:
                repl *= ctx.tp
            if meta.fsdp_dim is None or ctx.mode != "hier":
                repl *= data
            gsq += jnp.sum(jnp.square(g.astype(jnp.float32))) / repl
        gsq = node.allreduce(gsq, result="replicated")
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        new_params, new_m, new_v = adamw_update(
            params, grads, state["m"], state["v"], state["step"] + 1,
            lr=lr, weight_decay=weight_decay)
        new_state = {"params": new_params, "m": new_m, "v": new_v,
                     "step": state["step"] + 1}
        metrics = {"loss": loss_g / cnt_g, "gnorm": gnorm, "tokens": cnt_g}
        return new_state, metrics

    smapped = vc.smap(body, in_specs=(state_specs, bspec),
                      out_specs=(state_specs,
                                 {"loss": P(), "gnorm": P(), "tokens": P()}))
    return TrainStepBundle(fn=smapped, state_specs=state_specs,
                           batch_spec=bspec, model=model)


def make_step_bench(cfg: ModelConfig, vc, *, opts=(), unroll: int = 1,
                    lr: float = 3e-4, weight_decay: float = 0.1,
                    clip: float = 1.0, global_batch: int = 8, seq: int = 32,
                    seed: int = 0, schedule_sink=None):
    """Whole-train-step bench body for one cluster: forward + backward +
    gradient bridge + optimizer, as a ``repro.bench`` case.

    Returns ``(body, in_specs, out_specs, make_args, elems)`` with the
    state tree FLATTENED into separate top-level args (``BenchCase.compile``
    shards one plain ``PartitionSpec`` per arg) and ``elems`` = the model's
    global parameter element count (the family's recorded message size).
    Everything runs fp32 (the bench artifact's recorded dtype); the body
    returns three replicated f32 scalars — loss, grad norm, and a parameter
    checksum that keeps the whole optimizer update alive under DCE.

    ``unroll`` feeds the unit scan: the ``step_time`` family's eager
    baseline unrolls all units (``unroll=cfg.n_units``) so it differs from
    the prefetch schedule ONLY in gather placement — scan-vs-unroll is an
    orthogonal code-layout effect the family deliberately holds constant.

    With the ``stepgraph`` opt the scalar stats and the per-leaf gradient
    reductions are recorded into one ``CollectiveGraph`` and run as the
    bucketed/reordered schedule; ``schedule_sink`` (a list) receives the
    schedule ``report()`` dict at trace time for inspection.
    """
    ctx = cluster_ctx(vc, opts=opts)
    sizes = dict(zip(vc.axis_names, vc.axis_shapes))
    data = 1
    for a in ctx.fsdp_axes:
        data *= sizes[a]
    model = build(cfg, ctx, data=data)
    defs = model.defs
    pspecs = model.param_specs(tp_axis=ctx.tp_axis,
                               fsdp_axis=ctx.fsdp_axes[0]
                               if ctx.fsdp_axes else None)
    state_specs = {"params": pspecs, "m": pspecs, "v": pspecs, "step": P()}
    bspec = P(ctx.dp_axes)
    meta_leaves = jax.tree.leaves(defs,
                                  is_leaf=lambda x: isinstance(x, PMeta))
    world = Communicator.from_cluster(vc)
    node = world.split_type_shared()

    from repro.models.transformer import _loss  # local-body entry

    def step(state, batch):
        params = state["params"]

        def lf(p):
            return _loss(cfg, ctx, defs, p, {"tokens": batch},
                         unroll=unroll)

        (loss_sum, cnt), grads = jax.value_and_grad(lf, has_aux=True)(params)
        # scalar stats: pinned to the flat scheme so the step's lowering is
        # one fixed program per topology (auto would couple the bench body
        # to the tuning table's per-topology winner, and scatter-based
        # winners cannot scatter a 0-d operand anyway)
        if ctx.stepgraph:
            rec = world.record()
            rl = rec.allreduce(loss_sum, axes=world.axes, scheme="naive",
                               key="loss")
            rc = rec.allreduce(cnt, axes=world.axes, scheme="naive",
                               key="cnt")
            grads = ctx.reduce_grads(grads, meta_leaves, recorder=rec)
            res = rec.run()
            if schedule_sink is not None:
                schedule_sink.append(res.report())
            loss_g, cnt_g = res[rl], res[rc]
            grads = res.resolve(grads)
        else:
            loss_g = world.allreduce(loss_sum, scheme="naive")
            cnt_g = world.allreduce(cnt, scheme="naive")
            grads = ctx.reduce_grads(grads, meta_leaves)
        grads = jax.tree.map(lambda g: g / cnt_g, grads)
        gsq = jnp.float32(0.0)
        for g, meta in zip(jax.tree.leaves(grads), meta_leaves):
            repl = 1.0
            if meta.tp_dim is None and ctx.tp_axis:
                repl *= ctx.tp
            if meta.fsdp_dim is None:
                repl *= data
            gsq += jnp.sum(jnp.square(g.astype(jnp.float32))) / repl
        gsq = node.allreduce(gsq, scheme="naive")
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        new_params, _, _ = adamw_update(
            params, grads, state["m"], state["v"], state["step"] + 1,
            lr=lr, weight_decay=weight_decay)
        csum = jnp.float32(0.0)
        for leaf in jax.tree.leaves(new_params):
            csum += jnp.sum(leaf.astype(jnp.float32))
        csum = world.allreduce(csum, scheme="naive")
        return loss_g / cnt_g, gnorm, csum

    spec_leaves, spec_tree = jax.tree.flatten(
        state_specs, is_leaf=lambda x: isinstance(x, P))

    def body(*args):
        state = jax.tree.unflatten(spec_tree, args[:-1])
        return step(state, args[-1])

    in_specs = tuple(spec_leaves) + (bspec,)
    out_specs = (P(), P(), P())

    def make_args():
        params = model.init_params(seed)
        m, v = adamw_init(params)
        state = {"params": params, "m": m, "v": v,
                 "step": jnp.zeros((), jnp.int32)}
        # deterministic token stream (Knuth multiplicative hash of position)
        toks = (jnp.arange(global_batch * (seq + 1), dtype=jnp.uint32)
                * jnp.uint32(2654435761)) % jnp.uint32(cfg.vocab)
        tokens = toks.astype(jnp.int32).reshape(global_batch, seq + 1)
        return tuple(jax.tree.flatten(state)[0]) + (tokens,)

    pshapes = jax.eval_shape(model.init_params)
    elems = 0
    for leaf in jax.tree.leaves(pshapes):
        n = 1
        for d in leaf.shape:
            n *= d
        elems += n
    return body, in_specs, out_specs, make_args, elems


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeStepBundle:
    prefill: Any
    decode: Any
    param_specs: Any         # serve layout
    prefill_param_specs: Any  # train layout (prefill runs in it)
    cache_spec: Any
    batch_spec: Any
    model: Model
    s_max: int
    b_loc: int


def _dp_tuple(topo: MeshTopology) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in topo.axis_sizes)


def make_serve_steps(cfg: ModelConfig, topo: MeshTopology, mesh, *,
                     mode: str = "hier", global_batch: int, s_max: int,
                     unroll: int = 1, opts=(),
                     compute_dtype=jnp.bfloat16) -> ServeStepBundle:
    model = build_model(cfg, topo, mode, compute_dtype, opts)
    dp = _dp_tuple(topo)
    n_dp = 1
    for a in dp:
        n_dp *= topo.size(a)
    # small batches (long_500k: B=1) replicate over dp instead of sharding
    shard_batch = global_batch % n_dp == 0 and global_batch >= n_dp
    dp_b = dp if shard_batch else ()
    b_loc = global_batch // n_dp if shard_batch else global_batch
    bspec = batch_specs(cfg, topo)
    if not shard_batch:
        bspec = jax.tree.map(lambda s: P(), bspec,
                             is_leaf=lambda x: isinstance(x, P))
    pspecs_serve = model.param_specs(serve=True)
    pspecs_train = model.param_specs(serve=False)

    # decode cache: device-major layout (DP, TP, *local_shape)
    local_cache = jax.eval_shape(lambda: model.cache_init(b_loc, s_max))
    cache_spec = jax.tree.map(
        lambda _: P(dp_b if dp_b else None, "model"), local_cache)

    def prefill_body(params, batch):
        cache, logits = model.prefill_fn(params, batch, s_max, unroll=unroll)
        cache = jax.tree.map(lambda a: a[None, None], cache)
        return cache, logits

    def decode_body(params, cache, token, pos):
        cache = jax.tree.map(lambda a: a[0, 0], cache)
        new_cache, logits = model.decode_fn(params, cache, token, pos,
                                            unroll=unroll)
        new_cache = jax.tree.map(lambda a: a[None, None], new_cache)
        return new_cache, logits

    tok_spec = P(dp_b) if dp_b else P()
    logit_spec = P(dp_b) if dp_b else P()
    prefill = shard_map(
        prefill_body, mesh=mesh, in_specs=(pspecs_train, bspec),
        out_specs=(cache_spec, logit_spec), check_vma=False)
    decode = shard_map(
        decode_body, mesh=mesh,
        in_specs=(pspecs_serve, cache_spec, tok_spec, P()),
        out_specs=(cache_spec, logit_spec), check_vma=False)
    return ServeStepBundle(prefill=prefill, decode=decode,
                           param_specs=pspecs_serve,
                           prefill_param_specs=pspecs_train,
                           cache_spec=cache_spec, batch_spec=bspec,
                           model=model, s_max=s_max, b_loc=b_loc)
