"""End-to-end driver: train a ~150M-param qwen3-family model for a few
hundred steps on CPU with checkpoint/restart enabled.

    PYTHONPATH=src python examples/train_100m.py --steps 300

Kill it mid-run and re-invoke: it resumes from the last checkpoint with the
data stream fast-forwarded (loss curve continues seamlessly).
"""

import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.topology import MeshTopology
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_mesh_from_topo
from repro.runtime.steps import make_train_step
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="checkpoints/train_100m")
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b")
    cfg = dataclasses.replace(
        cfg, name="qwen3-150m", n_layers=12, d_model=768, n_heads=12,
        n_kv=4, head_dim=64, d_ff=3072, vocab=32768)
    print(f"params: {cfg.param_count()/1e6:.0f}M")

    topo = MeshTopology({"data": 1, "model": 1}, slow_axes=())
    mesh = make_mesh_from_topo(topo)
    bundle = make_train_step(cfg, topo, mesh, mode="hier", lr=6e-4,
                             compute_dtype=jnp.float32)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    report = train(bundle, steps=args.steps, data_cfg=data_cfg,
                   ckpt_dir=args.ckpt, save_every=50, log_every=10)
    base = float(np.log(cfg.vocab_padded))
    print(f"final loss {report.final_loss:.3f} (ln V = {base:.3f}); "
          f"resumed_from={report.resumed_from}")


if __name__ == "__main__":
    main()
