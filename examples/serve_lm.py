"""Serve a tiny LM with the continuous-batching engine (CPU, ~1min).

Submits heterogeneous-length prompts through the request queue, decodes
them together in fixed slots (finished slots are refilled mid-flight), and
shows the two serving guarantees this repo pins in tests:

* every request's token stream is IDENTICAL to running it alone — batching
  never changes outputs;
* the scheduler's measured decode latencies feed a session-local
  ``LiveTuner`` overlay, so ``scheme="auto"`` can track this session's
  real traffic without touching the committed ``TUNING_default.json``.

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.comm.tuning import topo_signature
from repro.models import build_by_name
from repro.serving.live_tuning import LiveTuner
from repro.serving.scheduler import ContinuousBatchingScheduler, generate


def main():
    model = build_by_name("qwen3-0.6b", reduced=True)
    params = model.init_params(0)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
               for n in (5, 11, 3, 8, 6)]

    tuner = LiveTuner(min_count=1)
    sched = ContinuousBatchingScheduler(model, params, slots=2, s_max=24,
                                        tuner=tuner)
    rids = [sched.queue.submit(p, 6) for p in prompts]
    results = sched.run()

    print(f"{len(prompts)} requests through 2 slots, "
          f"{len(sched.stats)} decode steps "
          f"(mean batch {np.mean([s.active for s in sched.stats]):.2f}):")
    for rid, p in zip(rids, prompts):
        solo = generate(model, params, [p], max_new=6, slots=1, s_max=24)
        same = np.array_equal(results[rid].tokens, solo.tokens)
        print(f"  req{rid} (prompt {p.size:2d} tok) -> "
              f"{results[rid].tokens[0].tolist()}  "
              f"{'== solo run' if same else 'MISMATCH'}")
        assert same, "continuous batching must not change outputs"

    k = sched._tuner_key
    est = tuner.estimate("serving", topo_signature(k["pods"], k["chips"]),
                         "float32", k["nbytes"], k["scheme"])
    print(f"live tuner: serving/{k['scheme']} decode EWMA {est:.0f} us; "
          f"overlay carries {len(tuner.overlay().entries)} entries "
          f"(committed table untouched)")


if __name__ == "__main__":
    main()
