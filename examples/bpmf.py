"""BPMF — Bayesian Probabilistic Matrix Factorization (paper §5.2.2).

Gibbs sampling over user/item factors on a two-tier mesh (2 nodes x 4
cores).  Each iteration samples the user factors (needs ALL item factors)
then the item factors (needs ALL user factors) — the two all-gathers the
paper accelerates:

* naive  (Ori_BPMF): flat allgather, every core a private copy of the full
  factor matrix;
* hybrid (Hy_BPMF): bridge-only exchange (``shared_all_gather``), one copy
  per node sharded over its cores, read at use;
* auto: ``scheme="auto"`` — the committed tuning table picks the gather
  scheme for this 2x4 shape (a MEASURED cell of the bench matrix).

All variants produce identical samples (same RNG); RMSE on held-out
entries falls.

    PYTHONPATH=src python examples/bpmf.py [--iters 10]
"""

import os

# appended: XLA honors the LAST duplicate flag, and this script's device
# count must win over anything inherited from the environment
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time      # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comm import Communicator, SharedWindow, tuning  # noqa: E402
from repro.core.plans import allgather_traffic    # noqa: E402
from repro.substrate.compat import make_mesh, shard_map  # noqa: E402

NODES, CORES = 2, 4
COMM = Communicator(fast_axis="core", slow_axis="node", pods=NODES,
                    chips=CORES)
D = 16           # latent dim
BETA = 100.0     # observation precision (matches noise sd 0.1)
LAM = 16.0       # prior precision (= D, the BPMF default scale)


def gather(x, scheme):
    """Allgather factor shards: (n_loc, D) -> (N, D)."""
    if scheme == "naive":
        return COMM.allgather(x, scheme="naive")
    if scheme == "auto":
        # tuning-table dispatch: normalize whatever class the table picked
        # back to the rank-order full matrix the sampler consumes
        out = COMM.allgather(x, scheme="auto")
        return out.read_rank_order() if isinstance(out, SharedWindow) \
            else out
    # hybrid: ONE shared copy per node (a SharedWindow), read at use
    return COMM.allgather(x, scheme="shared").read_rank_order()


def sample_factors(r_loc, mask_loc, other_full, key):
    """Posterior sample for this shard's rows given the other factor matrix.
    r_loc: (n_loc, M); other_full: (M, D)."""
    n_loc = r_loc.shape[0]
    vt = other_full  # (M, D)

    def one(r_i, m_i, k):
        prec = BETA * (vt.T * m_i) @ vt + LAM * jnp.eye(D)
        cov = jnp.linalg.inv(prec)
        mean = BETA * cov @ (vt.T @ (r_i * m_i))
        chol = jnp.linalg.cholesky(cov)
        return mean + chol @ jax.random.normal(k, (D,))

    keys = jax.random.split(key, n_loc)
    return jax.vmap(one)(r_loc, mask_loc, keys)


def bpmf(r, mask, scheme, mesh, iters, seed=0):
    N, M = r.shape

    def body(r_u, m_u, r_v, m_v):
        node = lax.axis_index("node")
        core = lax.axis_index("core")
        rank = node * CORES + core
        key = jax.random.PRNGKey(seed)
        ki = jax.random.fold_in(jax.random.PRNGKey(seed + 1), rank)
        u = 0.1 * jax.random.normal(ki, (N // (NODES * CORES), D))
        v = 0.1 * jax.random.normal(jax.random.fold_in(ki, 7),
                                    (M // (NODES * CORES), D))

        def it(carry, i):
            u, v, key, acc, n = carry
            key, k1, k2 = jax.random.split(key, 3)
            v_full = gather(v, scheme)                    # (M, D)
            u = sample_factors(r_u, m_u, v_full,
                               jax.random.fold_in(k1, rank))
            u_full = gather(u, scheme)                    # (N, D)
            v = sample_factors(r_v, m_v, u_full,
                               jax.random.fold_in(k2, rank))
            # posterior-predictive average after burn-in (BPMF's estimator)
            burned = i >= iters // 2
            pred = gather(u, scheme) @ gather(v, scheme).T
            acc = acc + jnp.where(burned, 1.0, 0.0) * pred
            n = n + jnp.where(burned, 1.0, 0.0)
            return (u, v, key, acc, n), None

        acc0 = jnp.zeros((N, M))
        (u, v, _, acc, n), _ = lax.scan(it, (u, v, key, acc0, 0.0),
                                        jnp.arange(iters))
        return (acc / jnp.maximum(n, 1.0))[None], gather(v, scheme)[None]

    spec = P(("node", "core"))
    f = shard_map(body, mesh=mesh,
                  in_specs=(spec, spec, spec, spec),
                  out_specs=(P(None), P(None)), check_vma=False)
    rj = jnp.asarray(r)
    mj = jnp.asarray(mask)
    pred, _ = jax.jit(f)(rj, mj, jnp.asarray(r.T.copy()),
                         jnp.asarray(mask.T.copy()))
    return np.asarray(pred[0])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--users", type=int, default=128)
    ap.add_argument("--items", type=int, default=128)
    args = ap.parse_args()

    mesh = make_mesh((NODES, CORES), ("node", "core"))
    rng = np.random.default_rng(0)
    u_true = rng.normal(size=(args.users, D)) / np.sqrt(D)
    v_true = rng.normal(size=(args.items, D)) / np.sqrt(D)
    r = (u_true @ v_true.T + 0.1 * rng.normal(size=(args.users,
                                                    args.items)))
    mask = (rng.uniform(size=r.shape) < 0.3).astype(np.float32)
    test_mask = ((rng.uniform(size=r.shape) < 0.1) * (1 - mask))
    r_obs = (r * mask).astype(np.float32)

    res = tuning.resolve_for(
        COMM, "allgather", elems=args.items * D // (NODES * CORES))
    print(f"scheme='auto' resolved the factor gather to {res.scheme!r} "
          f"[{res.source}] on this {NODES}x{CORES} shape")

    results = {}
    for scheme in ("naive", "hybrid", "auto"):
        t0 = time.time()
        pred = bpmf(r_obs, mask, scheme, mesh, args.iters)
        dt = time.time() - t0
        rmse = float(np.sqrt((((pred - r) ** 2) * test_mask).sum()
                             / test_mask.sum()))
        base = float(np.sqrt(((r ** 2) * test_mask).sum()
                             / test_mask.sum()))
        flat = scheme == "naive" or (
            scheme == "auto"
            and tuning.registry.get_scheme(res.scheme).result_class
            == "replicated")
        tr = allgather_traffic(scheme="naive" if flat else "hier",
                               num_nodes=NODES,
                               ranks_per_node=CORES,
                               bytes_per_rank=args.items * D * 4
                               // (NODES * CORES))
        results[scheme] = (dt, rmse)
        print(f"{scheme:6s}: TT({args.iters} iters)={dt*1e3:8.1f} ms  "
              f"RMSE={rmse:.4f} (baseline {base:.4f})  "
              f"intra-node copy bytes/gather={tr.fast_bytes:,}")
    ratio = results["naive"][0] / results["hybrid"][0]
    print(f"Ori_BPMF/Hy_BPMF time ratio: {ratio:.2f} "
          f"(paper Fig. 12: >1, growing with core count)")
    for scheme in ("hybrid", "auto"):
        assert abs(results["naive"][1] - results[scheme][1]) < 1e-4, \
            "schemes must produce identical samples"


if __name__ == "__main__":
    main()
