"""SUMMA distributed matmul (paper §5.2.1) — hybrid vs naive broadcasts.

The process grid is (nodes x cores) = (4, 4) over 16 fake CPU devices.
Each SUMMA round broadcasts an A-panel along the grid row and a B-panel down
the grid column:

* naive  (pure MPI, Ori_SUMMA): every core ends with a private panel copy
  (``naive_broadcast``);
* hybrid (paper, Hy_SUMMA): ONE shared panel copy per node, sharded over the
  node's cores (``shared_broadcast``), read at use (``shared_read``);
* pipelined (Hy_SUMMA + compute overlap): same shared panel window, but the
  read is FUSED into the panel product — ``Communicator.ag_matmul_rows``
  gathers the A-panel chunk-wise behind the per-chunk matmuls
  (``repro.comm.pipeline``), so the window load streams instead of
  completing before the first MXU cycle;
* auto: ``scheme="auto"`` — the tuning table picks the row-panel reduction
  scheme; this grid's 1x4 node shape is NOT in the committed bench matrix,
  so the pick comes from the ``core.plans`` closed forms (the modeled
  cold-start path), and the example prints which scheme won.

All schemes must produce C = A @ B exactly; the derived traffic model shows
the hybrid schemes deleting the intra-node copy bytes (paper Fig. 11's win).

    PYTHONPATH=src python examples/summa.py [--n 512] [--use-kernel]
                                            [--chunks 2]
"""

import os

# appended: XLA honors the LAST duplicate flag, and this script's device
# count must win over anything inherited from the environment
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=16")

import argparse  # noqa: E402
import time      # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comm import Communicator, SharedWindow, tuning  # noqa: E402
from repro.core.plans import broadcast_traffic  # noqa: E402
from repro.substrate.compat import make_mesh, shard_map  # noqa: E402

NODES, CORES = 4, 4   # grid rows = nodes (fast tier inside a row)
# a grid row is one shared-memory node: cores exchange panels in-node
ROW_COMM = Communicator(fast_axis="core", slow_axis=None, pods=1,
                        chips=CORES)


def summa(a, b, *, scheme: str, mesh, use_kernel: bool = False,
          chunks: int = 2):
    """a, b: (N, N) host arrays; grid: rows over 'node', cols over 'core'."""
    N = a.shape[0]
    bs = N // NODES  # square block per device row/col

    ar = a.reshape(NODES, bs, CORES, N // CORES).transpose(0, 2, 1, 3)
    br = b.reshape(NODES, N // NODES, CORES, N // CORES).transpose(0, 2, 1, 3)
    # device (i, j) holds A[i, j] (bs x N/CORES) and B[i, j]

    def step(a_blk, b_blk):
        i = lax.axis_index("node")
        j = lax.axis_index("core")
        a_blk, b_blk = a_blk[0, 0], b_blk[0, 0]
        cs = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        for k in range(CORES):  # SUMMA rounds over the inner grid dim
            # row broadcast of A[:, k] (owner core k) — intra-node tier
            a_src = jnp.where(j == k, a_blk, jnp.zeros_like(a_blk))
            # column broadcast of B[k, :] (owner node k) — bridge tier
            b_src = jnp.where(i == k, b_blk, jnp.zeros_like(b_blk))
            # raw-collective: pedagogical SUMMA baseline, raw by design
            b_panel = lax.psum(b_src, "node")
            if scheme == "auto":
                # tuning-table dispatch: shared-class picks come back as a
                # window (read at use), replicated picks as a plain panel
                out = ROW_COMM.allreduce(a_src, scheme="auto")
                a_panel = out.read() if isinstance(out, SharedWindow) \
                    else out
                cs = cs + a_panel @ b_panel
                continue
            if scheme == "pipelined":
                # Hy_SUMMA + overlap: the shared window's read is fused into
                # the panel product — per-chunk row gathers stream behind
                # the per-chunk matmuls (double-buffered)
                win = ROW_COMM.reduce_scatter(a_src, scheme="shared")
                cs = cs + ROW_COMM.ag_matmul_rows(
                    win.shard, b_panel, n_chunks=chunks,
                    use_kernel=use_kernel)
                continue
            if scheme == "naive":
                # raw-collective: pedagogical SUMMA baseline
                a_panel = lax.psum(a_src, "core")
            else:  # hybrid: one shared panel per node (a window), read at use
                a_panel = ROW_COMM.reduce_scatter(a_src,
                                                  scheme="shared").read()
            if use_kernel:
                from repro.kernels.ops import matmul as pallas_mm
                cs = cs + pallas_mm(a_panel, b_panel)
            else:
                cs = cs + a_panel @ b_panel
        return cs[None, None]

    f = shard_map(step, mesh=mesh,
                  in_specs=(P("node", "core"), P("node", "core")),
                  out_specs=P("node", "core"), check_vma=False)
    cj = jax.jit(f)(jnp.asarray(ar), jnp.asarray(br))
    # (NODES, CORES, bs, N/CORES) -> (N, N)
    return np.asarray(cj).transpose(0, 2, 1, 3).reshape(N, N)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--chunks", type=int, default=2,
                    help="overlap depth of the pipelined variant")
    args = ap.parse_args()

    mesh = make_mesh((NODES, CORES), ("node", "core"))
    rng = np.random.default_rng(0)
    a = rng.normal(size=(args.n, args.n)).astype(np.float32)
    b = rng.normal(size=(args.n, args.n)).astype(np.float32)
    want = a @ b

    panel_elems = (args.n // NODES) * (args.n // CORES)
    res = tuning.resolve_for(ROW_COMM, "psum", elems=panel_elems)
    print(f"scheme='auto' resolved the row-panel reduction to "
          f"{res.scheme!r} [{res.source}] for this 1x{CORES} node shape")

    for scheme in ("naive", "hybrid", "pipelined", "auto"):
        t0 = time.time()
        got = summa(a, b, scheme=scheme, mesh=mesh,
                    use_kernel=args.use_kernel, chunks=args.chunks)
        dt = time.time() - t0
        err = np.abs(got - want).max() / np.abs(want).max()
        panel = args.n * (args.n // CORES) * 4  # bytes per A panel
        flat = scheme == "naive" or (
            scheme == "auto"
            and tuning.registry.get_scheme(res.scheme).result_class
            == "replicated")
        tr = broadcast_traffic(scheme="naive" if flat else "hier",
                               num_nodes=NODES,
                               ranks_per_node=CORES, msg_bytes=panel)
        print(f"{scheme:9s}: {dt*1e3:8.1f} ms  rel_err={err:.2e}  "
              f"intra-node copy bytes/round={tr.fast_bytes:,}  "
              f"panel copies/node={tr.result_bytes_per_node // panel}")
    print("paper claim C2: the hybrid schemes delete all intra-node panel "
          "copies (pipelined additionally streams the window read behind "
          "the matmul; auto lets the tuning table choose); all schemes "
          "match A@B exactly.")


if __name__ == "__main__":
    main()
