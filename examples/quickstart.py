"""Quickstart: train a tiny qwen3-family model on synthetic data (CPU, ~1min)
and watch the loss fall well below ln(vocab); then generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.topology import MeshTopology
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_mesh_from_topo
from repro.runtime.steps import make_train_step
from repro.runtime.train_loop import train
from repro.serving.engine import greedy_generate


def main():
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=128,
                                           n_heads=4, vocab=512)
    topo = MeshTopology({"data": 1, "model": 1}, slow_axes=())
    mesh = make_mesh_from_topo(topo)
    bundle = make_train_step(cfg, topo, mesh, mode="hier", lr=3e-3,
                             compute_dtype=jnp.float32)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    report = train(bundle, steps=60, data_cfg=data_cfg, log_every=10)
    base = np.log(cfg.vocab_padded)
    print(f"\nfinal loss {report.final_loss:.3f} vs ln(V)={base:.3f} "
          f"(structure learned: {report.final_loss < base - 0.5})")

    # generate with the serving engine from the trained params (the
    # single-device ctx shares the exact param layout at tp=1)
    from repro.data.synthetic import SyntheticLM
    from repro.models.parallel import ParallelCtx
    from repro.models.transformer import build
    model1 = build(cfg, ParallelCtx.single())
    prompts = SyntheticLM(data_cfg).next_batch()["tokens"][:2, :32] \
        .astype(np.int32)
    res = greedy_generate(model1, report.state["params"], prompts, max_new=8)
    print("generated:", res.tokens.tolist())


if __name__ == "__main__":
    main()
