"""Benchmark harness — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV.  The collective sweep
(``repro.bench``: matrix topologies, traffic-validated, JSON artifact),
the paper-figure configs (Figs 7-10) and the SUMMA/BPMF applications
(Figs 11-12) run in subprocesses with fake multi-device CPU platforms;
wall time there is a scheduling proxy — the ``derived`` columns
(traffic-model bytes, copies per node) carry the hardware-independent
claim, and EXPERIMENTS.md §Roofline carries the TPU-calibrated numbers
from the dry-run artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + REPO
    env.pop("XLA_FLAGS", None)
    return env


def run_subprocess_csv(cmd: list[str]) -> None:
    proc = subprocess.run(cmd, capture_output=True, text=True, env=_env(),
                          timeout=3600)
    if proc.returncode != 0:
        print(f"SUBPROCESS-FAIL {' '.join(cmd)}: {proc.stderr[-500:]}",
              file=sys.stderr)
        return
    for line in proc.stdout.splitlines():
        if re.match(r"^[a-z0-9_]+,", line):
            print(line, flush=True)


def bench_collectives(quick: bool) -> None:
    """Matrix-driven sweep (repro.bench): every row is traffic-model
    cross-checked against the compiled HLO; the JSON artifact lands in
    BENCH_collectives.json."""
    reps = "5" if quick else "30"
    cmd = [sys.executable, "-m", "repro.bench", "--csv", "--reps", reps,
           "--out", os.path.join(REPO, "BENCH_collectives.json")]
    if quick:
        cmd.append("--quick")
    run_subprocess_csv(cmd)


def bench_figs(quick: bool) -> None:
    """The paper-figure configurations (Figs 7-10, up to 24 devices)."""
    reps = "5" if quick else "30"
    run_subprocess_csv([sys.executable, "-m",
                        "benchmarks._collective_bench", "--devices", "24",
                        "--reps", reps])


def bench_summa(quick: bool) -> None:
    n = "256" if quick else "512"
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "summa.py"),
         "--n", n], capture_output=True, text=True, env=_env(), timeout=3600)
    for line in proc.stdout.splitlines():
        m = re.match(r"(naive|hybrid)\s*:\s*([0-9.]+) ms\s+rel_err=(\S+)\s+"
                     r"intra-node copy bytes/round=([\d,]+)", line)
        if m:
            scheme, ms, err, fastb = m.groups()
            print(f"fig11_summa_{scheme}_n{n},{float(ms)*1e3:.0f},"
                  f"rel_err={err};intra_copy_bytes={fastb.replace(',', '')}",
                  flush=True)


def bench_bpmf(quick: bool) -> None:
    iters = "10" if quick else "30"
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "bpmf.py"),
         "--iters", iters], capture_output=True, text=True, env=_env(),
        timeout=3600)
    for line in proc.stdout.splitlines():
        m = re.match(r"(naive|hybrid)\s*:\s*TT\((\d+) iters\)=\s*([0-9.]+) ms"
                     r"\s+RMSE=([0-9.]+)", line)
        if m:
            scheme, it, ms, rmse = m.groups()
            print(f"fig12_bpmf_{scheme}_{it}iters,{float(ms)*1e3:.0f},"
                  f"rmse={rmse}", flush=True)


def bench_kernels(quick: bool) -> None:
    """Kernel oracle throughput on CPU + interpret-mode validation status.
    (Pallas kernels are TPU-target; interpret wall time is not meaningful.)"""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    B, H, T, hd = 1, 4, (256 if quick else 1024), 64
    q = jnp.asarray(rng.normal(size=(B, H, T, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, T, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, T, hd)).astype(np.float32))
    f = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True))
    f(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        out = f(q, k, v)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / reps * 1e6
    flops = 4 * B * H * T * T / 2 * hd
    print(f"kernel_attention_ref_T{T},{us:.0f},"
          f"gflops={flops/us*1e6/1e9:.1f};pallas=interpret-validated",
          flush=True)

    M = 512 if quick else 1024
    a = jnp.asarray(rng.normal(size=(M, M)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(M, M)).astype(np.float32))
    g = jax.jit(lambda x, y: x @ y)
    g(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = g(a, b)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / reps * 1e6
    print(f"kernel_matmul_ref_{M},{us:.0f},"
          f"gflops={2*M**3/us*1e6/1e9:.1f};pallas=interpret-validated",
          flush=True)


def bench_roofline_summary(quick: bool) -> None:
    """Per-cell roofline terms from the dry-run artifacts (the real perf
    report; see EXPERIMENTS.md)."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d):
        print("roofline_summary,0,missing (run repro.launch.dryrun first)",
              flush=True)
        return
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, fn)))
        if rec.get("status") != "ok" or rec.get("mesh") != "single":
            continue
        r = rec["roofline"]
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mode']}"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"{name},{bound*1e6:.0f},"
              f"dom={r['dominant']};frac={r['roofline_fraction']:.2f};"
              f"useful={r['useful_flops_ratio']:.2f}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="collectives|figs|summa|bpmf|kernels|roofline")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    benches = {"collectives": bench_collectives, "figs": bench_figs,
               "summa": bench_summa, "bpmf": bench_bpmf,
               "kernels": bench_kernels,
               "roofline": bench_roofline_summary}
    todo = [args.only] if args.only else list(benches)
    for name in todo:
        benches[name](args.quick)


if __name__ == "__main__":
    main()
