"""Paper-figure collective microbenchmarks (Figs 7-10) — thin wrapper.

All measurement machinery lives in ``repro.bench`` now: the calibrated
timer (single warmup, blocks on every output leaf, median-of-reps), the
VirtualCluster topologies, and the traffic-model/HLO cross-checks.  This
script only maps the paper's figure configurations onto ``repro.bench``
cases and prints the legacy ``name,us_per_call,derived`` CSV rows.

Run with a device count set by the parent:
    python -m benchmarks._collective_bench --devices 24 --fig fig7

Wall time on fake CPU devices is a scheduling proxy (no real ICI); the
``derived`` column carries the traffic-model bytes (``core.plans``) that
the roofline validates on real HW.  ``copies_per_node`` counts copies of
the FULL result a node holds (paper C1: naive = ranks_per_node, hybrid =
1) — the seed version divided by per-rank bytes and printed rank counts.
"""

import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=24)
ap.add_argument("--fig", default="all")
ap.add_argument("--reps", type=int, default=30)
args = ap.parse_args()

# appended: XLA honors the LAST duplicate flag, and --devices must win over
# anything inherited from the environment
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={args.devices}")

from repro.bench import report, suites  # noqa: E402
from repro.substrate import VirtualCluster  # noqa: E402


def fig7_cases():
    """One full node (8 cores): hybrid needs no exchange at all."""
    vc = VirtualCluster(pods=1, chips=8)
    return [c for e in (1, 64, 1024, 8192, 32768)
            for c in suites.allgather_cases(vc, e) if c.scheme != "hier"]


def fig8_cases():
    """One rank per node (worst case: no shared-memory advantage)."""
    return [c for nodes in (4, 8) for e in (64, 8192)
            for c in suites.allgather_cases(
                VirtualCluster(pods=nodes, chips=1), e)
            if c.scheme != "hier"]


def fig9_cases():
    """Fixed nodes, growing ranks-per-node: the hybrid advantage grows."""
    return [c for ppn in (2, 4, 8, 12) for e in (512, 16384)
            for c in suites.allgather_cases(VirtualCluster(pods=2,
                                                           chips=ppn), e)
            if c.scheme != "hier"]


def fig10_cases():
    """Irregularly populated nodes (padded + GatherPlan compaction): the
    24-core analogue of the paper's 24/16 split."""
    return list(suites.allgatherv_cases(VirtualCluster(pods=2, chips=8),
                                        4096, populations=(8, 6)))


FIGS = {"fig7": fig7_cases, "fig8": fig8_cases, "fig9": fig9_cases,
        "fig10": fig10_cases}


def main():
    figs = list(FIGS) if args.fig == "all" else [args.fig]
    for fig in figs:
        cases = []
        for c in FIGS[fig]():
            if c.cluster.available():
                cases.append(c)
            else:
                print(f"SKIP {fig}/{c.name}: needs "
                      f"{c.cluster.num_devices} devices", file=sys.stderr)
        if not cases:
            continue
        suite = suites.run_suite(cases, reps=args.reps)
        for row in report.csv_rows(suite):
            # legacy naming: the paper calls the shared scheme "hybrid"
            print(f"{fig}_{row}".replace("_shared_", "_hybrid_"),
                  flush=True)


if __name__ == "__main__":
    main()
