"""Subprocess collective microbenchmarks (paper Figs 7-10).

Run with a device count set by the parent:
    python -m benchmarks._collective_bench --devices 24 --fig fig7

Prints ``name,us_per_call,derived`` CSV rows.  Wall time on fake CPU devices
is a scheduling proxy (no real ICI); the ``derived`` column carries the
traffic-model bytes (plans.py) that the roofline validates on real HW.
"""

import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=24)
ap.add_argument("--fig", default="all")
ap.add_argument("--reps", type=int, default=30)
args = ap.parse_args()

# appended: XLA honors the LAST duplicate flag, and --devices must win over
# anything inherited from the environment
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={args.devices}")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.substrate.compat import shard_map  # noqa: E402

from repro.core import collectives as cc  # noqa: E402
from repro.core.plans import (GatherPlan, NodeMap,  # noqa: E402
                              allgather_traffic)

REPS = args.reps


def mesh_for(nodes: int, cores: int) -> Mesh:
    need = nodes * cores
    if len(jax.devices()) < need:
        raise SystemExit(f"this figure needs {need} devices; "
                         f"rerun with --devices {need} (got "
                         f"{len(jax.devices())})")
    devs = np.array(jax.devices()[:need]).reshape(nodes, cores)
    return Mesh(devs, ("node", "core"))


def timeit(fn, *xs) -> float:
    fn(*xs)[0].block_until_ready() if isinstance(fn(*xs), tuple) else \
        fn(*xs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*xs)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / REPS * 1e6  # us


def allgather_pair(nodes, cores, elems, scheme):
    """Per-rank contribution of ``elems`` doubles; returns a timed callable
    + its derived traffic."""
    mesh = mesh_for(nodes, cores)
    n_ranks = nodes * cores
    x = jnp.arange(n_ranks * elems, dtype=jnp.float64).astype(jnp.float32)
    spec = P(("node", "core"))

    if scheme == "naive":
        def body(v):
            return cc.naive_all_gather(v, fast_axis="core",
                                       slow_axis="node")
        out_spec = P(None)
    else:
        def body(v):
            return cc.shared_all_gather(v, fast_axis="core",
                                        slow_axis="node")
        out_spec = spec

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                          out_specs=out_spec, check_vma=False))
    tr = allgather_traffic(scheme="hier" if scheme == "hybrid" else "naive",
                           num_nodes=nodes, ranks_per_node=cores,
                           bytes_per_rank=elems * 8)
    return (lambda: f(x)), tr


def bench_fig7():
    """One full node (8 cores): hybrid needs no exchange at all."""
    for elems in (1, 64, 1024, 8192, 32768):
        for scheme in ("naive", "hybrid"):
            fn, tr = allgather_pair(1, 8, elems, scheme)
            us = timeit(lambda _=0: fn())
            print(f"fig7_allgather_1node_{scheme}_{elems},{us:.1f},"
                  f"fast_bytes={tr.fast_bytes};copies_per_node="
                  f"{tr.result_bytes_per_node // max(elems * 8, 1)}")


def bench_fig8():
    """One rank per node (worst case: no shared-memory advantage)."""
    for nodes in (4, 8):
        for elems in (64, 8192):
            for scheme in ("naive", "hybrid"):
                fn, tr = allgather_pair(nodes, 1, elems, scheme)
                us = timeit(lambda _=0: fn())
                print(f"fig8_allgather_{nodes}n1p_{scheme}_{elems},{us:.1f},"
                      f"slow_bytes={tr.slow_bytes}")


def bench_fig9():
    """Fixed nodes, growing ranks-per-node: the hybrid advantage grows."""
    for ppn in (2, 4, 8, 12):
        for elems in (512, 16384):
            for scheme in ("naive", "hybrid"):
                fn, tr = allgather_pair(2, ppn, elems, scheme)
                us = timeit(lambda _=0: fn())
                print(f"fig9_allgather_2n{ppn}p_{scheme}_{elems},{us:.1f},"
                      f"fast_bytes={tr.fast_bytes}")


def bench_fig10():
    """Irregularly populated nodes (padded + GatherPlan compaction)."""
    nodes, cores = 2, 8
    pops = (8, 6)  # 24-core analogue of the paper's 24/16 split
    mesh = mesh_for(nodes, cores)
    elems = 4096
    plan = GatherPlan(NodeMap.irregular(list(pops)), elem_per_rank=elems)
    plan.check()
    x = jnp.ones((nodes * cores * elems,), jnp.float32)
    valid = jnp.asarray(
        [[elems if c < p else 0 for c in range(cores)]
         for p in pops], jnp.int32).reshape(nodes * cores, 1)
    spec = P(("node", "core"))

    def hybrid(v, val):
        blocks, counts = cc.shared_all_gather_v(v, val, slow_axis="node")
        return blocks

    def naive(v, val):
        del val
        return cc.naive_all_gather(v, fast_axis="core", slow_axis="node")

    fh = jax.jit(shard_map(hybrid, mesh=mesh, in_specs=(spec, spec),
                           out_specs=P(None, "core"), check_vma=False))
    fn_ = jax.jit(shard_map(naive, mesh=mesh, in_specs=(spec, spec),
                            out_specs=P(None), check_vma=False))
    for name, f in (("naive", fn_), ("hybrid", fh)):
        us = timeit(lambda _=0: f(x, valid))
        print(f"fig10_allgatherv_irregular_{name},{us:.1f},"
              f"counts={'/'.join(str(c) for c in plan.counts())}")


FIGS = {"fig7": bench_fig7, "fig8": bench_fig8, "fig9": bench_fig9,
        "fig10": bench_fig10}


def main():
    figs = list(FIGS) if args.fig == "all" else [args.fig]
    for f in figs:
        FIGS[f]()


if __name__ == "__main__":
    main()
